"""Unified NomadProjection front end: strategy selection, event callbacks,
checkpoint/resume equivalence, and the fit_distributed deprecation shim.

Everything here runs on the single in-process CPU device — the sharded
strategy is exercised on a 1-device mesh, where it must agree with the
local strategy bit-for-bit (same RNG stream, same loss composition). The
full multi-device paths are covered by the `slow` subprocess selftests.
"""

import os

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs.base import NomadConfig
from repro.core.nomad import FitResult, NomadProjection
from repro.core.strategy import (
    EpochEndEvent,
    FitCallbacks,
    HierarchicalStrategy,
    LocalStrategy,
    ShardedStrategy,
    resolve_strategy,
)
from repro.data.synthetic import gaussian_mixture

N, DIM = 1500, 16

CFG = NomadConfig(
    n_points=N,
    dim=DIM,
    n_clusters=4,
    n_neighbors=10,
    n_noise=16,
    n_exact_negatives=4,
    batch_size=256,
    n_epochs=4,
)


@pytest.fixture(scope="module")
def data():
    x, labels = gaussian_mixture(N, DIM, n_components=4, seed=0)
    return x, labels


@pytest.fixture(scope="module")
def one_device_mesh():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))


# ---------------------------------------------------------------------------
# Strategy selection
# ---------------------------------------------------------------------------


def test_auto_resolves_local_on_one_device():
    # the in-process test runner has a single CPU device
    assert isinstance(resolve_strategy("auto", CFG), LocalStrategy)
    assert isinstance(resolve_strategy("local", CFG), LocalStrategy)
    assert isinstance(resolve_strategy("sharded", CFG), ShardedStrategy)
    assert isinstance(resolve_strategy("hierarchical", CFG), HierarchicalStrategy)


def test_auto_with_mesh_resolves_sharded(one_device_mesh):
    s = resolve_strategy("auto", CFG, mesh=one_device_mesh)
    assert isinstance(s, ShardedStrategy) and not isinstance(s, HierarchicalStrategy)


def test_strategy_instance_passthrough():
    s = LocalStrategy()
    assert resolve_strategy(s, CFG) is s


def test_unknown_strategy_rejected():
    with pytest.raises(ValueError, match="unknown strategy"):
        resolve_strategy("pmap", CFG)
    with pytest.raises(ValueError, match="unknown strategy"):
        NomadConfig(strategy="pmap")


def test_infonc_requires_local(data, one_device_mesh):
    x, _ = data
    proj = NomadProjection(CFG.replace(method="infonc"), strategy="sharded",
                           mesh=one_device_mesh)
    with pytest.raises(ValueError, match="strategy='local'"):
        proj.fit(x)


def test_default_mesh_divides_clusters():
    from repro.core.strategy import default_mesh

    mesh = default_mesh(CFG)
    n_shards = int(np.prod(list(mesh.shape.values())))
    assert CFG.n_clusters % n_shards == 0
    assert n_shards <= len(jax.devices())


# ---------------------------------------------------------------------------
# Local ≡ sharded on a 1-device mesh (strategy equivalence)
# ---------------------------------------------------------------------------


def test_local_and_sharded_agree_on_one_device(data, one_device_mesh):
    x, _ = data
    loc = NomadProjection(CFG, strategy="local").fit(x)
    sh = NomadProjection(CFG, strategy="sharded", mesh=one_device_mesh).fit(
        x, index=loc.index
    )
    assert sh.strategy == "sharded" and sh.n_shards == 1
    assert sh.mesh_shape == (1,) and sh.mesh_axes == ("data",)
    np.testing.assert_array_equal(loc.embedding, sh.embedding)
    np.testing.assert_allclose(loc.losses, sh.losses, rtol=1e-6)


# ---------------------------------------------------------------------------
# Event API
# ---------------------------------------------------------------------------


class Recorder(FitCallbacks):
    def __init__(self):
        self.starts, self.ends, self.refreshes, self.checkpoints = [], [], [], []

    def on_epoch_start(self, ev):
        self.starts.append(ev)

    def on_epoch_end(self, ev):
        self.ends.append(ev)

    def on_means_refresh(self, ev):
        self.refreshes.append(ev)

    def on_checkpoint(self, ev):
        self.checkpoints.append(ev)


def test_callbacks_receive_unpermuted_embedding(data):
    x, _ = data
    rec = Recorder()
    res = NomadProjection(CFG).fit(x, callbacks=rec)
    assert [e.epoch for e in rec.ends] == list(range(CFG.n_epochs))
    for ev in rec.ends:
        assert isinstance(ev, EpochEndEvent)
        # the unpermuted (N, out_dim) view — NOT the (K·C, d) padded buffer
        assert ev.embedding.shape == (N, CFG.out_dim)
        assert ev.strategy == "local"
    np.testing.assert_array_equal(rec.ends[-1].embedding, res.embedding)
    assert [e.epoch for e in rec.starts] == list(range(CFG.n_epochs))
    assert rec.starts[0].lr0 == pytest.approx(CFG.resolved_lr0())
    assert all(ev.n_refreshes == 1 for ev in rec.refreshes)  # default: 1/epoch


def test_wants_embedding_false_skips_materialisation(data):
    x, _ = data

    class Cheap(FitCallbacks):
        wants_embedding = False

        def __init__(self):
            self.embs = []

        def on_epoch_end(self, ev):
            self.embs.append(ev.embedding)

    cb = Cheap()
    NomadProjection(CFG.replace(n_epochs=2)).fit(x, callbacks=cb)
    assert cb.embs == [None, None]


def test_legacy_callback_deprecated_and_unpermuted(data):
    x, _ = data
    got = []
    with pytest.warns(DeprecationWarning, match="callback"):
        NomadProjection(CFG.replace(n_epochs=2)).fit(
            x, callback=lambda e, emb, loss: got.append((e, emb.shape, loss))
        )
    assert [g[0] for g in got] == [0, 1]
    assert all(g[1] == (N, CFG.out_dim) for g in got)


# ---------------------------------------------------------------------------
# Checkpointing + resume
# ---------------------------------------------------------------------------


class _Kill(Exception):
    pass


class _KillAfter(FitCallbacks):
    wants_embedding = False

    def __init__(self, epoch):
        self.epoch = epoch

    def on_epoch_end(self, ev):
        if ev.epoch == self.epoch:
            raise _Kill(f"killed after epoch {ev.epoch}")


def test_kill_resume_matches_uninterrupted(data, tmp_path):
    """Kill a fit after epoch 3, resume via from_checkpoint, and get the
    exact embedding of an uninterrupted run (same seed/fold_in schedule)."""
    x, _ = data
    base = CFG.replace(n_epochs=6, checkpoint_every_epochs=2)

    full = NomadProjection(base.replace(checkpoint_dir=str(tmp_path / "a"))).fit(x)
    assert full.checkpoint_epochs == [1, 3, 5]

    ckdir = str(tmp_path / "b")
    cfg = base.replace(checkpoint_dir=ckdir)
    with pytest.raises(_Kill):
        NomadProjection(cfg).fit(x, callbacks=_KillAfter(3))
    assert os.path.exists(os.path.join(ckdir, "index.npz"))

    est = NomadProjection.from_checkpoint(ckdir)
    assert est.cfg.n_epochs == 6 and est.cfg.checkpoint_dir == ckdir
    res = est.fit(x)  # from_checkpoint ⇒ resume by default
    assert res.resumed and res.start_epoch == 4
    assert len(res.losses) == 2  # epochs 4, 5
    np.testing.assert_array_equal(full.embedding, res.embedding)


def test_resume_false_restarts_from_scratch(data, tmp_path):
    x, _ = data
    cfg = CFG.replace(n_epochs=3, checkpoint_dir=str(tmp_path), checkpoint_every_epochs=1)
    r1 = NomadProjection(cfg).fit(x)
    r2 = NomadProjection(cfg).fit(x, resume=False)
    assert not r2.resumed and r2.start_epoch == 0
    np.testing.assert_array_equal(r1.embedding, r2.embedding)


def test_resume_without_checkpoint_dir_raises(data):
    x, _ = data
    with pytest.raises(ValueError, match="checkpoint_dir"):
        NomadProjection(CFG).fit(x, resume=True)


def test_checkpoint_events_and_provenance(data, tmp_path):
    x, _ = data
    rec = Recorder()
    cfg = CFG.replace(checkpoint_dir=str(tmp_path), checkpoint_every_epochs=2)
    res = NomadProjection(cfg).fit(x, callbacks=rec)
    assert res.checkpoint_dir == str(tmp_path)
    assert res.checkpoint_epochs == [1, 3]  # every 2, + final epoch (3)
    assert [e.epoch for e in rec.checkpoints] == [1, 3]
    assert all(e.directory == str(tmp_path) for e in rec.checkpoints)


def test_stale_index_cache_rebuilt_not_reused(data, tmp_path):
    """Reusing a checkpoint_dir with different data must not silently fit
    against the cached index of the old dataset."""
    x, _ = data
    cfg = CFG.replace(n_epochs=2, checkpoint_dir=str(tmp_path))
    NomadProjection(cfg).fit(x)  # writes index.npz for (N, DIM)
    x2, _ = gaussian_mixture(800, DIM, n_components=4, seed=1)
    cfg2 = cfg.replace(n_points=800)
    with pytest.warns(UserWarning, match="index cache"):
        res = NomadProjection(cfg2).fit(x2, resume=False)
    assert res.embedding.shape == (800, CFG.out_dim)
    assert res.index.n_points == 800  # cache was rebuilt, not reused


def test_from_checkpoint_without_config_metadata(tmp_path):
    from repro.checkpoint import Checkpointer

    ck = Checkpointer(str(tmp_path))
    ck.save(0, {"theta": np.zeros((8, 2), np.float32)}, metadata={"epoch": 0})
    with pytest.raises(ValueError, match="no stored config"):
        NomadProjection.from_checkpoint(str(tmp_path))


def test_pod_axis_autodetected_with_explicit_shard_axes(data):
    x, _ = data
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("pod", "data"))
    strat = ShardedStrategy(mesh=mesh, shard_axes=("data",))
    res = NomadProjection(CFG.replace(n_epochs=1), strategy=strat).fit(x)
    assert strat.pod_axis == "pod"  # not silently dropped from the sharding
    assert res.n_shards == 1


# ---------------------------------------------------------------------------
# Out-of-core: fit(store) ≡ fit(ndarray), every strategy
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def data_store(data, tmp_path_factory):
    from repro.data.store import write_sharded

    x, _ = data
    d = tmp_path_factory.mktemp("fit_store")
    return write_sharded(x, str(d / "corpus"), rows_per_shard=400)


def test_fit_from_store_equals_fit_from_ndarray_local(data, data_store):
    """The acceptance criterion: NomadProjection.fit on a sharded on-disk
    store returns a FitResult bit-equal to the in-memory fit. The shared
    cfg.chunk_rows pins the f32 accumulation order of the streamed build +
    PCA init, so only the byte source differs."""
    x, _ = data
    cfg = CFG.replace(chunk_rows=512)
    ra = NomadProjection(cfg).fit(x)
    rb = NomadProjection(cfg).fit(data_store)
    assert ra.index_build_strategy == rb.index_build_strategy == "streamed"
    np.testing.assert_array_equal(ra.embedding, rb.embedding)
    np.testing.assert_allclose(ra.losses, rb.losses, rtol=0)
    for f in ("knn_idx", "knn_w", "counts", "centroids", "perm"):
        np.testing.assert_array_equal(
            getattr(ra.index, f), getattr(rb.index, f), err_msg=f
        )


@pytest.mark.parametrize("build_strategy", ["local", "sharded"])
def test_fit_from_store_equals_ndarray_every_build_strategy(
    data, data_store, build_strategy
):
    x, _ = data
    cfg = CFG.replace(
        n_epochs=2, chunk_rows=512, build_strategy=build_strategy
    )
    ra = NomadProjection(cfg).fit(x)
    rb = NomadProjection(cfg).fit(data_store)
    np.testing.assert_array_equal(ra.embedding, rb.embedding)


def test_fit_from_store_equals_ndarray_sharded_strategy(
    data, data_store, one_device_mesh
):
    x, _ = data
    cfg = CFG.replace(n_epochs=2, chunk_rows=512)
    ra = NomadProjection(cfg, strategy="sharded", mesh=one_device_mesh).fit(x)
    rb = NomadProjection(cfg, strategy="sharded", mesh=one_device_mesh).fit(
        data_store
    )
    assert ra.strategy == rb.strategy == "sharded"
    np.testing.assert_array_equal(ra.embedding, rb.embedding)


def test_fit_from_memmap_streams(data, tmp_path):
    """An np.memmap input is auto-wrapped into a store: the fit streams it
    (and matches the same-chunking in-memory fit bit-for-bit)."""
    x, _ = data
    path = str(tmp_path / "x.npy")
    np.save(path, x)
    mm = np.load(path, mmap_mode="r")
    cfg = CFG.replace(n_epochs=2, chunk_rows=512)
    ra = NomadProjection(cfg).fit(mm)
    rb = NomadProjection(cfg).fit(x)
    assert ra.index_build_strategy == "streamed"
    np.testing.assert_array_equal(ra.embedding, rb.embedding)


def test_fit_store_checkpoint_resume_and_cache(data_store, tmp_path, data):
    """The checkpoint/resume path works from a disk-backed corpus: the
    second fit reuses the store-backed index cache (fingerprint-checked)
    and reproduces the run bit-for-bit."""
    cfg = CFG.replace(
        n_epochs=2, chunk_rows=512, checkpoint_dir=str(tmp_path / "ck")
    )
    r1 = NomadProjection(cfg).fit(data_store)
    assert r1.index_build_strategy == "streamed"
    r2 = NomadProjection(cfg).fit(data_store, resume=False)
    assert r2.index_build_strategy == "cache"
    np.testing.assert_array_equal(r1.embedding, r2.embedding)


# ---------------------------------------------------------------------------
# The unified front end's surface
# ---------------------------------------------------------------------------


def test_fit_transform(data):
    x, _ = data
    cfg = CFG.replace(n_epochs=2)
    emb = NomadProjection(cfg).fit_transform(x)
    res = NomadProjection(cfg).fit(x)
    np.testing.assert_array_equal(emb, res.embedding)


def test_fit_result_metadata(data):
    x, _ = data
    res = NomadProjection(CFG.replace(n_epochs=2)).fit(x)
    assert isinstance(res, FitResult)
    assert res.strategy == "local" and res.n_shards == 1
    assert res.mesh_shape is None and res.start_epoch == 0 and not res.resumed
    assert res.checkpoint_epochs == [] and res.checkpoint_dir == ""


def test_method_from_config(data):
    x, _ = data
    cfg = CFG.replace(n_epochs=2, method="infonc")
    res = NomadProjection(cfg).fit(x)
    assert np.isfinite(res.embedding).all()
    with pytest.raises(ValueError, match="unknown method"):
        NomadConfig(method="umap")


def test_fit_distributed_shim_warns_and_matches(data, one_device_mesh):
    x, _ = data
    from repro.core.distributed import fit_distributed

    ref = NomadProjection(CFG, strategy="sharded", mesh=one_device_mesh).fit(x)
    with pytest.warns(DeprecationWarning, match="fit_distributed"):
        emb, index, losses = fit_distributed(CFG, x, one_device_mesh,
                                             shard_axes=("data",), index=ref.index)
    np.testing.assert_array_equal(emb, ref.embedding)
    assert losses == ref.losses

"""The end-to-end pipeline's differential test layer.

The acceptance bar this file pins down:

* **streamed ≡ materialized** — for every architecture family in
  ``PIPELINE_WORKLOADS`` (dense / SSM / MoE), embedding a corpus straight
  into a sharded store and fitting from it is **bit-for-bit** the map the
  old collect-the-matrix-then-fit path produces, and the store's bytes
  are exactly ``embed_corpus``'s matrix;
* **one validation gate** — NaN and float64 corpora fail a store-backed
  fit through ``prepare_inputs`` with the *same actionable error* the
  in-memory path raises;
* **the inverse head is reproducible** — fixed seed ⇒ bit-identical
  parameters, checkpoint→reload ≡ in-memory bit-for-bit, and the
  round-trip R² (``roundtrip_score``) clears a committed floor (the same
  quantity ``benchmarks/pipeline.py`` gates in CI via ``score_leaves``);
* **the public frozen-index query** — ``FrozenMap.neighbors`` reports
  exactly the ids/dists the transform path reports for the same queries;
* **explore serves** — ``MapService.explore`` decodes + looks up through
  a checkpoint-loaded handle; a map without an inverse head fails with
  the training hint;
* **RSS stays O(chunk)** — the streamed example's peak host RSS stays
  measurably below the materializing path's (interposer subprocess, the
  PR-5 pattern).
"""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import PIPELINE_WORKLOADS
from repro.core.nomad import NomadProjection, prepare_inputs
from repro.data.embeddings import embed_corpus
from repro.data.store import MemmapStore
from repro.pipeline import (
    corpus_for,
    embed_chunks,
    embed_to_store,
    init_embedder,
    inverse_from_frozen,
    load_inverse,
    roundtrip_score,
    run_pipeline,
    save_inverse,
    train_inverse,
)
from repro.serve.frozen import FrozenMap
from repro.service import MapService

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# committed floor for the inverse round-trip R² at the fixture's scale;
# benchmarks/pipeline.py gates the full-size per-family scores in CI
ROUNDTRIP_R2_FLOOR = 0.15


def tiny(name: str):
    """A CI-sized copy of a registered workload (topology preserved)."""
    return dataclasses.replace(
        PIPELINE_WORKLOADS[name],
        n_docs=256,
        seq_len=32,
        doc_batch=64,
        n_epochs=2,
        n_clusters=8,
    )


# ---------------------------------------------------------------------------
# Tentpole differential: streamed embed→store→fit ≡ materialize-then-fit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(PIPELINE_WORKLOADS))
def test_streamed_fit_bit_equals_materialized_fit(name, tmp_path):
    """Per architecture family: the streaming pipeline must change the
    map by exactly nothing. Shard size is deliberately ≠ doc_batch ≠
    chunk_rows — none of the three blockings may leak into the bits."""
    w = tiny(name)
    tokens, _ = corpus_for(w)
    params, acfg = init_embedder(w)
    store = embed_to_store(
        params, acfg, tokens, str(tmp_path / "st"),
        doc_batch=w.doc_batch, rows_per_shard=100,
    )
    mat = embed_corpus(
        params, acfg,
        [tokens[i : i + w.doc_batch] for i in range(0, w.n_docs, w.doc_batch)],
    )
    # stage-1 differential: the store holds embed_corpus's exact bytes
    np.testing.assert_array_equal(store.materialize(), mat)

    cfg = w.nomad_config(w.n_docs, mat.shape[1], chunk_rows=64, seed=0)
    e_streamed = NomadProjection(cfg).fit(store).embedding
    e_materialized = NomadProjection(cfg).fit(mat).embedding
    np.testing.assert_array_equal(e_streamed, e_materialized)


def test_embed_chunks_matches_explicit_batches(tmp_path):
    """A (N, S) token array and the equivalent explicit batch list stream
    identical chunks (the doc_batch slicing is the only difference)."""
    w = tiny("pipeline_phi4_mini")
    tokens, _ = corpus_for(w)
    params, acfg = init_embedder(w)
    auto = list(embed_chunks(params, acfg, tokens, doc_batch=w.doc_batch))
    explicit = list(
        embed_chunks(
            params, acfg,
            [tokens[i : i + w.doc_batch] for i in range(0, w.n_docs, w.doc_batch)],
        )
    )
    assert len(auto) == len(explicit)
    for a, b in zip(auto, explicit):
        np.testing.assert_array_equal(a, b)


def test_embed_worker_error_reraises_in_consumer(tmp_path):
    """A poisoned forward (wrong token rank) fails the consumer loop with
    the worker's exception — the Prefetcher contract — instead of hanging
    the pipeline or committing a half-written store."""
    w = tiny("pipeline_phi4_mini")
    params, acfg = init_embedder(w)
    bad = [np.zeros((4, 8, 3), np.int32)]  # 3-D tokens: embed_in raises
    with pytest.raises(Exception):
        list(embed_chunks(params, acfg, bad))
    out = str(tmp_path / "st")
    with pytest.raises(Exception):
        embed_to_store(params, acfg, bad, out)
    assert not os.path.exists(os.path.join(out, "meta.json"))  # no commit


# ---------------------------------------------------------------------------
# One validation gate: NaN / float64 corpora fail stores and arrays alike
# ---------------------------------------------------------------------------


def test_nan_gate_same_error_for_store_and_ndarray(tmp_path):
    x = np.random.default_rng(0).normal(size=(200, 16)).astype(np.float32)
    x[13, 5] = np.nan
    with pytest.raises(ValueError) as e_arr:
        prepare_inputs(x, caller="fit")
    np.save(str(tmp_path / "bad.npy"), x)
    with pytest.raises(ValueError) as e_store:
        prepare_inputs(
            MemmapStore(str(tmp_path / "bad.npy")), caller="fit", chunk_rows=64
        )
    assert str(e_arr.value) == str(e_store.value)
    assert "non-finite" in str(e_arr.value)


def test_float64_gate_same_error_for_store_and_ndarray(tmp_path):
    x = np.random.default_rng(0).normal(size=(64, 8))  # float64
    with pytest.raises(ValueError) as e_arr:
        prepare_inputs(x, caller="fit")
    np.save(str(tmp_path / "bad64.npy"), x)
    with pytest.raises(ValueError) as e_store:
        prepare_inputs(MemmapStore(str(tmp_path / "bad64.npy")), caller="fit")
    assert str(e_arr.value) == str(e_store.value)
    assert "float64" in str(e_arr.value)


# ---------------------------------------------------------------------------
# The inverse head + explore path (one shared tiny pipeline run)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pipeline_run(tmp_path_factory):
    w = tiny("pipeline_phi4_mini")
    d = str(tmp_path_factory.mktemp("pipeline"))
    return run_pipeline(
        w, d, inverse_steps=300, nomad_overrides={"n_epochs": 4}
    )


def test_run_pipeline_artifacts(pipeline_run):
    r = pipeline_run
    assert r.store.shape == (r.workload.n_docs, r.workload.d_model)
    assert set(r.stage_s) == {"embed", "fit", "inverse_train"}
    assert os.path.exists(os.path.join(r.checkpoint_dir, "index.npz"))
    assert os.path.exists(os.path.join(r.checkpoint_dir, "inverse.npz"))


def test_inverse_fixed_seed_is_deterministic(pipeline_run):
    fz = pipeline_run.frozen
    a = inverse_from_frozen(fz, hidden=(32,), steps=50, seed=7)
    b = inverse_from_frozen(fz, hidden=(32,), steps=50, seed=7)
    c = inverse_from_frozen(fz, hidden=(32,), steps=50, seed=8)
    for (wa, ba), (wb, bb) in zip(a.layers, b.layers):
        np.testing.assert_array_equal(wa, wb)
        np.testing.assert_array_equal(ba, bb)
    assert any(
        not np.array_equal(wa, wc) for (wa, _), (wc, _) in zip(a.layers, c.layers)
    )


def test_inverse_roundtrip_clears_floor(pipeline_run):
    r = pipeline_run
    score = roundtrip_score(r.inverse, r.fit.embedding, r.store.materialize())
    assert score == pytest.approx(r.roundtrip_score)
    assert score >= ROUNDTRIP_R2_FLOOR, (
        f"inverse round-trip R² {score:.3f} fell under the committed floor "
        f"{ROUNDTRIP_R2_FLOOR} — the 2D→embedding head no longer recovers "
        "the corpus structure"
    )


def test_inverse_checkpoint_reload_bit_equal(pipeline_run, tmp_path):
    inv = pipeline_run.inverse
    reloaded = load_inverse(pipeline_run.checkpoint_dir)
    assert reloaded.hidden == inv.hidden
    assert reloaded.seed == inv.seed and reloaded.train_steps == inv.train_steps
    for (wa, ba), (wb, bb) in zip(inv.layers, reloaded.layers):
        np.testing.assert_array_equal(wa, wb)
        np.testing.assert_array_equal(ba, bb)
    np.testing.assert_array_equal(inv.mu_in, reloaded.mu_in)
    np.testing.assert_array_equal(inv.sd_in, reloaded.sd_in)
    # decode is the same function: identical outputs on identical inputs
    q = np.asarray([[0.0, 0.0], [1.5, -2.0]], np.float32)
    np.testing.assert_array_equal(inv.decode(q), reloaded.decode(q))


def test_inverse_load_missing_is_actionable(tmp_path):
    assert load_inverse(str(tmp_path), missing_ok=True) is None
    with pytest.raises(FileNotFoundError, match="train_inverse"):
        load_inverse(str(tmp_path))


def test_inverse_decode_validates(pipeline_run):
    inv = pipeline_run.inverse
    with pytest.raises(ValueError, match="expected"):
        inv.decode(np.zeros((3, 5), np.float32))
    with pytest.raises(ValueError, match="NaN"):
        inv.decode(np.asarray([[np.nan, 0.0]], np.float32))


def test_train_inverse_validates_pairs():
    with pytest.raises(ValueError, match="matched"):
        train_inverse(np.zeros((5, 2), np.float32), np.zeros((6, 8), np.float32))


# ---------------------------------------------------------------------------
# Public frozen-index kNN: FrozenMap.neighbors
# ---------------------------------------------------------------------------


def test_neighbors_matches_transform_report(pipeline_run):
    """The public query must be the transform path's neighbor report,
    bit-for-bit — same kernels, same order, same padding convention."""
    from repro.serve.server import MapServer

    fz = pipeline_run.frozen
    q = pipeline_run.store.materialize()[:32]
    res = MapServer(fz).transform(q, seed=0)
    ids, dists = fz.neighbors(q)
    np.testing.assert_array_equal(ids, res.neighbor_ids)
    np.testing.assert_array_equal(dists, res.neighbor_dists)


def test_neighbors_self_lookup_and_shapes(pipeline_run):
    fz = pipeline_run.frozen
    x = pipeline_run.store.materialize()
    ids, dists = fz.neighbors(x[7], k=3)  # 1-D query → 1-D result
    assert ids.shape == (3,) and dists.shape == (3,)
    assert ids[0] == 7 and dists[0] == pytest.approx(0.0, abs=1e-2)
    with pytest.raises(ValueError, match="expected"):
        fz.neighbors(np.zeros((2, fz.dim + 1), np.float32))
    with pytest.raises(ValueError, match="NaN"):
        fz.neighbors(np.full((fz.dim,), np.nan, np.float32))
    with pytest.raises(ValueError, match="capacity"):
        fz.neighbors(x[0], k=fz.capacity + 1)


# ---------------------------------------------------------------------------
# Service explore: checkpoint-loaded handle serves "what lives here?"
# ---------------------------------------------------------------------------


def test_service_explore_from_checkpoint(pipeline_run):
    svc = MapService()
    try:
        handle = svc.registry.load(pipeline_run.checkpoint_dir)
        assert handle.describe()["has_inverse"] is True
        theta = pipeline_run.fit.embedding
        out = svc.explore(theta[:4], k=5)
        assert out.embedding.shape == (4, pipeline_run.frozen.dim)
        assert out.neighbor_ids.shape == (4, 5)
        assert (out.neighbor_ids >= -1).all()
        assert out.map_version == handle.version
        # the decoded vector's neighborhood is the frozen index's answer
        ids, dists = pipeline_run.frozen.neighbors(out.embedding, k=5)
        np.testing.assert_array_equal(ids, out.neighbor_ids)
        np.testing.assert_array_equal(dists, out.neighbor_dists)
    finally:
        svc.close()


def test_service_explore_without_inverse_is_actionable(pipeline_run):
    svc = MapService()
    try:
        svc.registry.add(pipeline_run.frozen)  # in-process add: no head
        assert svc.registry.get().describe()["has_inverse"] is False
        with pytest.raises(ValueError, match="inverse head"):
            svc.explore([0.0, 0.0])
    finally:
        svc.close()


def test_http_explore_endpoint(pipeline_run):
    pytest.importorskip("fastapi")
    pytest.importorskip("httpx")
    from fastapi.testclient import TestClient

    from repro.service.app import create_app

    svc = MapService()
    svc.registry.load(pipeline_run.checkpoint_dir)
    theta = pipeline_run.fit.embedding
    with TestClient(create_app(svc)) as c:
        r = c.post("/explore", json={"coords": [theta[0].tolist()], "k": 3})
        assert r.status_code == 200, r.text
        body = r.json()
        assert len(body["neighbor_ids"][0]) == 3
        assert body["map_version"] == svc.registry.active_version
        # strict JSON: dead edges are -1.0, never Infinity
        assert all(
            d >= 0.0 or d == -1.0 for d in body["neighbor_dists"][0]
        )
        r = c.post("/explore", json={"coords": [[0.0, 0.0, 0.0]]})
        assert r.status_code == 400
        r = c.post("/explore", json={"coords": [[0.0, 0.0]], "map_version": "nope"})
        assert r.status_code == 404
    svc.close()


# ---------------------------------------------------------------------------
# RSS regression: the streamed example must stay under the materializing path
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_streamed_example_rss_below_materialized(tmp_path):
    """Runs examples/embed_and_map.py --rss-compare in a subprocess and
    asserts the streamed embed's peak host RSS (ru_maxrss watermark,
    sampled before the materializing embed runs in the same process)
    stays measurably below the materializing path's.

    Launched through the ``python -c`` interposer: a fork()ed child
    inherits the parent's RSS as its initial ru_maxrss, so spawning
    straight from a multi-GB pytest process would floor both phases at
    pytest's own RSS and void the comparison (the PR-5 pattern)."""
    out = str(tmp_path / "rss.json")
    interpose = (
        "import subprocess, sys; "
        "sys.exit(subprocess.run(sys.argv[1:]).returncode)"
    )
    r = subprocess.run(
        [
            sys.executable, "-c", interpose,
            sys.executable, "examples/embed_and_map.py",
            "--rss-compare", "--train-steps", "0",
            "--docs", "16384", "--seq-len", "16", "--d-model", "256",
            "--n-layers", "2", "--doc-batch", "256",
            "--workdir", str(tmp_path / "work"),
            "--json", out,
        ],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO,
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    with open(out) as f:
        res = json.load(f)
    rss = res["rss_compare"]
    assert rss["streamed_peak_mb"] > 0 and rss["monolithic_peak_mb"] > 0
    # the materializing path holds the chunk list AND the concatenated
    # (N, D) matrix (16 MB each at this size) the streamed path never
    # allocates; demand a clear margin over allocator jitter
    assert rss["monolithic_peak_mb"] - rss["streamed_peak_mb"] >= 12.0, rss

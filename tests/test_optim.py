"""Optimizer substrate: schedules, AdamW (fp32/int8 moments), SGD,
block-quantisation bounds, gradient compression with error feedback."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim import AdamW, SGD, constant, linear_decay, warmup_cosine
from repro.optim.compression import _dequant, _quant, compressed_psum
from repro.optim.quantized import dequantize_int8, quantize_int8


def test_linear_decay_endpoints():
    s = linear_decay(10.0, 100)
    assert float(s(0)) == 10.0
    assert abs(float(s(50)) - 5.0) < 1e-6
    assert float(s(100)) == 0.0
    assert float(s(150)) == 0.0  # clamped


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < float(s(50)) < float(s(10))


@given(st.integers(0, 2**31 - 1), st.sampled_from([(64,), (7, 33), (3, 5, 17)]))
@settings(max_examples=25, deadline=None)
def test_quantize_roundtrip_error_bound(seed, shape):
    x = jax.random.normal(jax.random.key(seed), shape) * 3
    q = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q)) - np.asarray(x))
    # per-block bound: scale/2 = absmax/254
    assert err.max() <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-7
    assert q.q.dtype == jnp.int8


def test_quantize_sqrt_scaled_nonneg():
    x = jnp.abs(jax.random.normal(jax.random.key(1), (300,))) * 5
    q = quantize_int8(x, sqrt_scaled=True)
    back = np.asarray(dequantize_int8(q))
    assert (back >= 0).all()
    # error bound is absolute in sqrt space: |√x̂−√x| ≤ δ = √xmax/127
    # ⇒ |x̂−x| ≤ 2√xmax·δ + δ²  (relative error blows up only for x ≈ 0,
    # exactly where Adam's v is noise anyway)
    delta = float(jnp.sqrt(jnp.max(x))) / 127.0
    bound = 2 * float(jnp.sqrt(jnp.max(x))) * delta + delta**2
    assert np.abs(back - np.asarray(x)).max() <= bound + 1e-6


def _rosenbrockish(p):
    return jnp.sum((p["a"] - 1.0) ** 2) + 0.5 * jnp.sum(p["b"] ** 2)


def test_adamw_converges_fp32():
    params = {"a": jnp.zeros((4, 4)), "b": jnp.ones((8,))}
    opt = AdamW(schedule=constant(0.05), weight_decay=0.0, moment_dtype="float32")
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(_rosenbrockish)(params)
        params, state = opt.update(params, g, state)
    assert float(_rosenbrockish(params)) < 1e-3


def test_adamw_int8_moments_track_fp32():
    k = jax.random.key(0)
    w0 = jax.random.normal(k, (512, 256))  # big enough to hit the quant path
    tgt = jax.random.normal(jax.random.key(1), (512, 256))

    def loss(p):
        return jnp.mean((p["w"] - tgt) ** 2)

    trajs = {}
    for mdt in ("float32", "int8"):
        p = {"w": w0}
        opt = AdamW(schedule=constant(0.01), weight_decay=0.0, moment_dtype=mdt)
        s = opt.init(p)
        for _ in range(60):
            g = jax.grad(loss)(p)
            p, s = opt.update(p, g, s)
        trajs[mdt] = float(loss(p))
    assert trajs["int8"] < 1.3 * trajs["float32"] + 1e-4, trajs


def test_sgd_with_schedule_is_paper_update():
    sched = linear_decay(1.0, 10)
    opt = SGD(schedule=sched)
    p = {"t": jnp.asarray([2.0])}
    s = opt.init(p)
    g = {"t": jnp.asarray([1.0])}
    p, s = opt.update(p, g, s)  # count=1 → lr = 0.9
    np.testing.assert_allclose(np.asarray(p["t"]), [2.0 - 0.9], rtol=1e-6)


def test_compression_error_feedback_telescopes():
    """Over T steps, Σ sent ≈ Σ grads (bias is carried, not lost)."""
    rng = np.random.default_rng(0)
    total_g = np.zeros(1000, np.float32)
    total_sent = np.zeros(1000, np.float32)
    r = np.zeros(1000, np.float32)
    for _ in range(30):
        g = rng.normal(0, 1, 1000).astype(np.float32)
        acc = g + r
        q, scale, pad = _quant(jnp.asarray(acc))
        sent = np.asarray(_dequant(q, scale, pad, (1000,)))
        r = acc - sent
        total_g += g
        total_sent += sent
    # residual bound: ≤ one quantisation step of the last accumulated value
    np.testing.assert_allclose(total_sent + r, total_g, rtol=1e-5, atol=1e-4)
    assert np.abs(r).max() < 0.1


def test_compressed_psum_single_axis():
    import functools
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("d",))
    g = {"w": jnp.linspace(-2, 2, 512)}
    r = jax.tree.map(jnp.zeros_like, g)

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()), check_rep=False)
    def run(g, r):
        return compressed_psum(g, "d", r)

    red, new_r = run(g, r)
    np.testing.assert_allclose(np.asarray(red["w"]), np.asarray(g["w"]), atol=0.02)

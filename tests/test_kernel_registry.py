"""Kernel registry subsystem tests.

* every registered kernel's Pallas path matches its jnp oracle across the
  spec's shape grid (interpret mode on CPU — same bodies Mosaic compiles),
* tile-size dispatch honors explicit/env/config overrides,
* the autotuner sweeps the tile grid and its on-disk cache round-trips,
* ``losses.nomad_mean_term`` dispatches through the registry with pallas
  and jnp agreeing (the Eq. 3 hot term — acceptance criterion).
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import NomadConfig
from repro.core import losses
from repro.kernels import autotune, registry

ALL_KERNELS = registry.names()


# ---------------------------------------------------------------------------
# Correctness oracle across the shape grid
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_KERNELS)
def test_every_kernel_registers_complete_spec(name):
    spec = registry.get(name)
    assert callable(spec.ref) and callable(spec.make_inputs)
    assert "" in spec.default_tiles, "needs a fallback-backend default"
    assert spec.check_shapes and spec.bench_shapes
    if spec.pallas is None:  # jnp-only: the seam exists, no fused path yet
        assert not registry.has_pallas(name)
        return
    assert callable(spec.pallas)
    assert len(spec.tile_candidates) >= 2, "autotune grid must be a real sweep"


@pytest.mark.parametrize("name", ALL_KERNELS)
def test_pallas_matches_oracle_across_shape_grid(name):
    spec = registry.get(name)
    if spec.pallas is None:
        pytest.skip("jnp-only kernel: no pallas path to validate")
    for i, sig in enumerate(spec.check_shapes):
        args = spec.make_inputs(jax.random.key(17 * i + 3), sig)
        registry.validate(name, args, interpret=True)


@pytest.mark.parametrize("name", ALL_KERNELS)
def test_pallas_matches_oracle_for_every_tile_candidate(name):
    """Tile sizes change the tiling, never the math — any autotune winner
    is safe to deploy."""
    spec = registry.get(name)
    if spec.pallas is None:
        pytest.skip("jnp-only kernel: no pallas path to validate")
    sig = spec.check_shapes[0]
    args = spec.make_inputs(jax.random.key(5), sig)
    for tiles in spec.tile_candidates:
        registry.validate(name, args, tiles=tiles, interpret=True)


def test_jnp_only_kernel_always_resolves_jnp(monkeypatch):
    """capacity_admit registered pallas=None: every override resolves jnp
    and validate() refuses (nothing to compare)."""
    assert not registry.has_pallas("capacity_admit")
    assert registry.resolve("capacity_admit", "pallas") == "jnp"
    monkeypatch.setenv("REPRO_KERNELS", "pallas")
    assert registry.resolve("capacity_admit") == "jnp"
    spec = registry.get("capacity_admit")
    args = spec.make_inputs(jax.random.key(0), spec.check_shapes[0])
    out = registry.dispatch("capacity_admit", *args, impl="pallas")
    assert out.shape == args[0].shape and out.dtype == bool
    with pytest.raises(ValueError, match="jnp-only"):
        registry.validate("capacity_admit", args)


# ---------------------------------------------------------------------------
# Dispatch + override resolution
# ---------------------------------------------------------------------------


def test_normalize_impl_accepts_legacy_bools():
    assert registry.normalize_impl(True) == "pallas"
    assert registry.normalize_impl(False) == "jnp"
    assert registry.normalize_impl(None) == "auto"
    assert registry.normalize_impl("auto") == "auto"
    assert registry.normalize_impl("ref") == "jnp"
    with pytest.raises(ValueError):
        registry.normalize_impl("cuda")


def test_resolve_explicit_beats_env(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "jnp")
    monkeypatch.setenv("REPRO_KERNEL_PAIRWISE", "jnp")
    assert registry.resolve("pairwise", "pallas") == "pallas"


def test_resolve_per_kernel_env_beats_global(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "jnp")
    monkeypatch.setenv("REPRO_KERNEL_PAIRWISE", "pallas")
    assert registry.resolve("pairwise") == "pallas"
    assert registry.resolve("cauchy_mean") == "jnp"


def test_resolve_backend_policy_on_cpu(monkeypatch):
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    monkeypatch.delenv("REPRO_KERNEL_PAIRWISE", raising=False)
    want = "jnp" if jax.default_backend() == "cpu" else "pallas"
    assert registry.resolve("pairwise") == want


def test_config_threads_impl():
    assert NomadConfig().resolved_kernel_impl() == "auto"
    assert NomadConfig(kernel_impl="pallas").resolved_kernel_impl() == "pallas"
    # the legacy bool still resolves, but is deprecated
    with pytest.warns(DeprecationWarning, match="use_pallas"):
        assert NomadConfig(use_pallas=False).resolved_kernel_impl() == "jnp"
    # kernel_impl supersedes the legacy bool
    with pytest.warns(DeprecationWarning, match="use_pallas"):
        assert NomadConfig(use_pallas=True, kernel_impl="jnp").resolved_kernel_impl() == "jnp"


def test_dispatch_unknown_kernel_raises():
    with pytest.raises(KeyError, match="unknown kernel"):
        registry.get("fused_sparse_sgd_scatter")


# ---------------------------------------------------------------------------
# nomad_mean_term through the registry (acceptance criterion)
# ---------------------------------------------------------------------------


def _mean_term_inputs(B=512, K=1024, d=2, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.key(seed), 4)
    theta = jax.random.normal(k1, (B, d), jnp.float32) * 3.0
    means = jax.random.normal(k2, (K, d), jnp.float32) * 3.0
    w = jax.random.uniform(k3, (K,), jnp.float32)
    own = jax.random.randint(k4, (B,), 0, K)
    return theta, means, w, own


def test_nomad_mean_term_pallas_matches_jnp_oracle():
    theta, means, w, own = _mean_term_inputs()
    got = losses.nomad_mean_term(theta, means, w, own, impl="pallas")
    want = losses.nomad_mean_term(theta, means, w, own, impl="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_nomad_mean_term_grad_matches_across_impls():
    theta, means, w, own = _mean_term_inputs(B=256, K=512, seed=7)

    def f(impl):
        return jax.grad(
            lambda th: jnp.sum(jnp.sin(losses.nomad_mean_term(th, means, w, own, impl)))
        )(theta)

    np.testing.assert_allclose(
        np.asarray(f("pallas")), np.asarray(f("jnp")), rtol=1e-4, atol=1e-6
    )


def test_nomad_mean_term_legacy_bool_still_works():
    theta, means, w, own = _mean_term_inputs(B=100, K=64, seed=3)
    got = losses.nomad_mean_term(theta, means, w, own, True)
    want = losses.nomad_mean_term(theta, means, w, own, False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Autotune
# ---------------------------------------------------------------------------


@pytest.fixture
def tune_env(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    autotune.clear_memory_cache()
    yield path
    autotune.clear_memory_cache()


def test_autotune_sweep_picks_a_candidate(tune_env):
    spec = registry.get("pairwise")
    entry = autotune.sweep(spec, spec.check_shapes[0], interpret=True)
    assert entry["tiles"] in [dict(t) for t in spec.tile_candidates]
    assert entry["us"] is not None and entry["us"] > 0
    assert entry["n_candidates"] == len(spec.tile_candidates)


def test_autotune_cache_roundtrips_through_disk(tune_env):
    spec = registry.get("pairwise")
    sig = spec.check_shapes[0]
    tiles1 = autotune.tiles_for(spec, sig)

    on_disk = json.loads(tune_env.read_text())
    assert on_disk["version"] == autotune.CACHE_VERSION
    key = autotune.cache_key("pairwise", registry.backend(), sig)
    entry = on_disk["entries"][key]
    assert entry["tiles"] == dict(tiles1)
    assert entry["src"] == autotune.source_hash(spec)

    # a fresh process (simulated: cleared memory) reloads the disk winner
    autotune.clear_memory_cache()
    assert autotune.tiles_for(spec, sig) == tiles1


def test_autotune_disabled_uses_backend_defaults(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune.json"))
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    autotune.clear_memory_cache()
    spec = registry.get("kmeans_assign")
    tiles = autotune.tiles_for(spec, spec.check_shapes[0])
    assert tiles == dict(spec.tiles_for_backend(registry.backend()))
    assert not (tmp_path / "tune.json").exists()  # nothing written
    autotune.clear_memory_cache()


def test_dispatch_with_explicit_tiles_skips_autotuner(monkeypatch):
    """tiles= pins the tiling — no tuner, no cache, still correct."""
    theta, means, w, own = _mean_term_inputs(B=64, K=128, seed=11)
    got = registry.dispatch(
        "cauchy_mean", theta, means, w, own, impl="pallas", tiles={"bb": 64, "bk": 128}
    )
    want = registry.dispatch("cauchy_mean", theta, means, w, own, impl="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)

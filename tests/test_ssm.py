"""Mamba-2 SSD correctness: the chunked state-space-duality algorithm must
equal the naive step-by-step recurrence, for any chunk size, including
state carry-over (prefill → decode) — the core identity of arXiv:2405.21060."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, reduced
from repro.models import ssm as S


def _naive_recurrence(x_h, B_mat, C_mat, dt, A, h0):
    """y_t = C_t·h_t + …, h_t = exp(dt_t A) h_{t-1} + dt_t x_t ⊗ B_t."""
    Bsz, T, H, P = x_h.shape
    N = B_mat.shape[-1]
    h = np.asarray(h0, np.float64).copy()
    ys = np.zeros((Bsz, T, H, P))
    xs = np.asarray(x_h, np.float64)
    Bm = np.asarray(B_mat, np.float64)
    Cm = np.asarray(C_mat, np.float64)
    dts = np.asarray(dt, np.float64)
    Am = np.asarray(A, np.float64)
    for t in range(T):
        decay = np.exp(dts[:, t, :] * Am)  # (B, H)
        h = h * decay[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", xs[:, t] * dts[:, t, :, None], Bm[:, t]
        )
        ys[:, t] = np.einsum("bhpn,bn->bhp", h, Cm[:, t])
    return ys, h


def _inputs(Bsz=2, T=32, H=3, P=4, N=5, seed=0):
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (Bsz, T, H, P))
    Bm = jax.random.normal(ks[1], (Bsz, T, N)) * 0.5
    Cm = jax.random.normal(ks[2], (Bsz, T, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (Bsz, T, H)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
    return x, Bm, Cm, dt, A


@pytest.mark.parametrize("chunk", [1, 4, 8, 32])
def test_ssd_equals_recurrence(chunk):
    x, Bm, Cm, dt, A = _inputs()
    h0 = jnp.zeros((2, 3, 4, 5))
    y, hT = S.ssd_scan(x, Bm, Cm, dt, A, h0, chunk)
    y_ref, h_ref = _naive_recurrence(x, Bm, Cm, dt, A, h0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(hT), h_ref, rtol=2e-4, atol=2e-5)


@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 16]))
@settings(max_examples=15, deadline=None)
def test_ssd_chunk_invariance(seed, chunk):
    """Different chunkings must agree (the duality is exact, not approx)."""
    x, Bm, Cm, dt, A = _inputs(seed=seed)
    h0 = jnp.zeros((2, 3, 4, 5))
    y1, h1 = S.ssd_scan(x, Bm, Cm, dt, A, h0, 32)  # single chunk
    y2, h2 = S.ssd_scan(x, Bm, Cm, dt, A, h0, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4, atol=2e-5)


def test_ssd_state_carry_prefill_to_decode():
    """ssm_block over [0:T) then decode steps ≡ ssm_block over [0:T+4)."""
    cfg = reduced(ARCHS["mamba2-2.7b"], n_layers=1, ssm_chunk=4)  # 4 | 32 and 4 | 36
    p = S.init_ssm(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 36, cfg.d_model))
    y_full, _ = S.ssm_block(p, x, cfg)
    y_pre, st = S.ssm_block(p, x[:, :32], cfg)
    outs = [y_pre]
    for t in range(32, 36):
        y_t, st = S.ssm_decode_block(p, x[:, t : t + 1], cfg, st)
        outs.append(y_t)
    y_cat = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_cat), np.asarray(y_full), rtol=3e-3, atol=3e-3
    )


def test_ssd_decay_stability_long_sequence():
    """Long-range: state stays bounded (A < 0 ⇒ contraction)."""
    x, Bm, Cm, dt, A = _inputs(T=256, seed=3)
    h0 = jnp.zeros((2, 3, 4, 5))
    y, hT = S.ssd_scan(x, Bm, Cm, dt, A, h0, 32)
    assert np.isfinite(np.asarray(y)).all()
    assert float(jnp.max(jnp.abs(hT))) < 1e3

"""Roofline infrastructure tests: the HLO cost parser against XLA's own
numbers (loop-free), against analytic FLOPs (looped), against a handwritten
HLO fixture (collectives + trip counts), and the term computation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.analysis import HW_V5E, model_flops, roofline_terms
from repro.roofline.hlo_cost import CostReport, analyze_hlo


def _xla_cost(comp):
    """``Compiled.cost_analysis()`` returns a dict on recent jax, a
    one-element list of dicts on older releases."""
    ca = comp.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_parser_matches_xla_loop_free():
    D = 256
    f = jax.jit(lambda a, b, c: jax.nn.relu(a @ b) @ c)
    sds = jax.ShapeDtypeStruct((D, D), jnp.float32)
    comp = f.lower(sds, sds, sds).compile()
    rep = analyze_hlo(comp.as_text())
    ca = _xla_cost(comp)
    assert abs(rep.flops - ca["flops"]) / ca["flops"] < 0.02
    assert abs(rep.bytes - ca["bytes accessed"]) / ca["bytes accessed"] < 0.1
    assert abs(rep.dot_flops - 2 * 2 * D**3) / (4 * D**3) < 0.01


def test_parser_multiplies_scan_trip_count():
    """THE reason this parser exists: XLA counts while bodies once."""
    D, L = 128, 12
    sds = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def g(x, ws):
        return jax.lax.scan(lambda x, w: (jnp.tanh(x @ w), None), x, ws)[0]

    comp = jax.jit(g).lower(sds, jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
    rep = analyze_hlo(comp.as_text())
    want = L * 2 * D**3
    assert abs(rep.dot_flops - want) / want < 0.02
    xla = _xla_cost(comp)["flops"]
    assert xla < rep.flops / 3  # demonstrates XLA's undercount


FIXTURE = """
HloModule fixture

%body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%p), index=1
  %d = f32[64,64]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[64,64]{1,0} all-reduce(%d), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,64]) tuple(%i2, %ar)
}

%cond (p2: (s32[], f32[64,64])) -> pred[] {
  %p2 = (s32[], f32[64,64]) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x0: f32[64,64]) -> (s32[], f32[64,64]) {
  %x0 = f32[64,64]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[64,64]) tuple(%zero, %x0)
  %ag = f32[128,64]{1,0} all-gather(%x0), replica_groups={}, dimensions={0}
  ROOT %w = (s32[], f32[64,64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
"""


def test_fixture_trip_counts_and_collectives():
    rep = analyze_hlo(FIXTURE)
    # dot: 2*64^3 per trip × 5 trips
    assert abs(rep.dot_flops - 5 * 2 * 64**3) < 1e-3
    # all-reduce inside the loop: input bytes 64*64*4 × 5; all-gather: output
    # bytes 128*64*4 once
    want_ar = 5 * 64 * 64 * 4
    want_ag = 128 * 64 * 4
    assert abs(rep.coll_by_type["all-reduce"] - want_ar) < 1e-3
    assert abs(rep.coll_by_type["all-gather"] - want_ag) < 1e-3
    assert rep.unknown_trip_whiles == 0


def test_roofline_terms_and_dominance():
    rep = CostReport(flops=197e12 * 0.01, bytes=819e9 * 0.05, collective_bytes=50e9 * 0.002)
    t = roofline_terms(rep, n_chips=256, model_fl=197e12 * 0.01 * 256 * 0.5)
    assert abs(t.compute_s - 0.01) < 1e-9
    assert abs(t.memory_s - 0.05) < 1e-9
    assert abs(t.collective_s - 0.002) < 1e-9
    assert t.dominant == "memory"
    assert abs(t.useful_ratio - 0.5) < 1e-9
    # roofline fraction: useful-compute time / bound = (0.5·0.01)/0.05
    assert abs(t.roofline_fraction - 0.1) < 1e-9


def test_model_flops_sanity():
    from repro.configs import ARCHS, SHAPES

    cfg = ARCHS["phi4-mini-3.8b"]
    mf_train = model_flops(cfg, SHAPES["train_4k"])
    # ≈ 6 · N_active · tokens; phi4 ≈ 3.8B params, 1M tokens → ~2.6e16
    assert 1e16 < mf_train < 6e16, mf_train
    mf_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert mf_dec < mf_train / 1000
    # MoE: active ≪ total
    moe = ARCHS["mixtral-8x7b"].param_counts()
    assert moe["active"] < 0.4 * moe["total"]
    # jamba: 398B-class total
    jam = ARCHS["jamba-1.5-large-398b"].param_counts()
    assert 3.0e11 < jam["total"] < 5.5e11, jam

"""Shared test configuration.

Two responsibilities:

* Put ``src/`` on ``sys.path`` so the suite runs from a plain checkout
  (``pip install -e .`` makes this a no-op).
* Make ``hypothesis`` an *optional* dependency: when it is not installed,
  a minimal stub is injected into ``sys.modules`` whose ``@given`` replaces
  the property test with a clean ``pytest.skip`` — the remaining
  (non-property) tests in those modules still collect and run.
"""

from __future__ import annotations

import os
import sys
import types

import pytest

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def _install_hypothesis_stub() -> None:
    mod = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")

    def given(*_a, **_kw):
        def deco(fn):
            def wrapper():
                pytest.skip("hypothesis not installed (property test skipped)")

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    def settings(*_a, **_kw):
        def deco(fn):
            return fn

        return deco

    class _AnyStrategy:
        """st.integers(...), st.floats(...), … — inert placeholders."""

        def __getattr__(self, _name):
            return lambda *a, **kw: None

    strategies.__getattr__ = _AnyStrategy().__getattr__  # type: ignore[attr-defined]
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


try:  # pragma: no cover - trivially environment-dependent
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()

"""Registry-wide differential parity harness.

Every Pallas kernel in the registry is validated against its jnp oracle
over its full ``check_shapes`` grid × its ``dtype_grid`` — in interpret
mode, so CPU CI exercises the exact kernel bodies Mosaic compiles on TPU.

This file is also the *coverage gate*: the parametrization is built from
``registry.names()`` at collection time, and module-level asserts fail
collection outright if a kernel registers without parity coverage —

* no ``check_shapes`` at all (nothing to validate against the oracle), or
* no *ragged* signature (every dim a multiple of 8), which would leave
  the padding/masking path (``kernels/padding.py``) untested.

Registering a new kernel therefore automatically enrolls it here; there
is no opt-in step to forget. jnp-only kernels (``pallas=None``) are
exempt from Pallas parity but must still declare shapes (their seam tests
live next to the spec).
"""

from __future__ import annotations

import jax
import pytest

from repro.kernels import registry


def _is_float_dtype(dt: str) -> bool:
    return "float" in dt  # float32, float16, bfloat16, float64


def _with_dtype(sig, dt):
    """Rewrite every floating dtype in the signature to ``dt``; integer
    args (ids, indices) keep theirs."""
    return tuple((shape, dt if _is_float_dtype(d0) else d0) for shape, d0 in sig)


def _is_ragged(sig) -> bool:
    return any(dim % 8 != 0 for shape, _ in sig for dim in shape)


# ---------------------------------------------------------------------------
# Coverage gate — runs at collection; a bare `registry.register(...)` with
# missing or lane-aligned-only shapes kills the whole test session loudly.
# ---------------------------------------------------------------------------

_PARAMS = []
for _name in registry.names():
    _spec = registry.get(_name)
    assert _spec.check_shapes, (
        f"kernel {_name!r} registered without parity coverage: "
        "KernelSpec.check_shapes is empty — every kernel must declare the "
        "shape grid tests/test_kernel_parity.py validates against the oracle"
    )
    if _spec.pallas is None:
        continue  # jnp-only seam: nothing to diff against the oracle yet
    assert any(_is_ragged(s) for s in _spec.check_shapes), (
        f"kernel {_name!r} has no ragged check shape (a dim not divisible "
        "by 8) — the pad/mask path would ship untested; add one to "
        "KernelSpec.check_shapes"
    )
    assert _spec.dtype_grid, f"kernel {_name!r} has an empty dtype_grid"
    for _i, _sig in enumerate(_spec.check_shapes):
        for _dt in _spec.dtype_grid:
            _PARAMS.append(
                pytest.param(_name, _i, _dt, id=f"{_name}-shape{_i}-{_dt}")
            )


@pytest.mark.parametrize("name,shape_idx,dtype", _PARAMS)
def test_pallas_matches_oracle(name, shape_idx, dtype):
    spec = registry.get(name)
    sig = _with_dtype(spec.check_shapes[shape_idx], dtype)
    args = spec.make_inputs(jax.random.key(shape_idx), sig)
    registry.validate(name, args, interpret=True)  # raises on mismatch


def test_every_registered_kernel_is_enrolled():
    """The parametrization spans exactly the Pallas kernels of the registry."""
    enrolled = {p.values[0] for p in _PARAMS}
    expected = {n for n in registry.names() if registry.has_pallas(n)}
    assert enrolled == expected


def test_jnp_only_kernels_resolve_to_ref_everywhere(monkeypatch):
    """The coverage exemption is exactly the pallas=None set — and those
    kernels must run their ref under every override."""
    monkeypatch.setenv("REPRO_KERNELS", "pallas")
    for name in registry.names():
        if not registry.has_pallas(name):
            assert registry.resolve(name, "pallas") == "jnp"

"""Data pipeline: loader determinism/shard-disjointness, prefetcher, and the
embedding bridge (zoo model → vectors → NOMAD-compatible)."""

import numpy as np

import jax

from repro.configs import ARCHS, reduced
from repro.data.embeddings import embed_corpus
from repro.data.loader import Prefetcher, TokenStream
from repro.models import lm


def test_loader_determinism_and_shards():
    ts = TokenStream(vocab_size=1000, seq_len=64)
    b1 = ts.batch(step=5, batch_size=32)
    b2 = ts.batch(step=5, batch_size=32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ts.batch(step=6, batch_size=32)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # shards are deterministic and distinct
    s0 = ts.batch(step=5, batch_size=32, shard=0, n_shards=4)
    s1 = ts.batch(step=5, batch_size=32, shard=1, n_shards=4)
    assert s0["tokens"].shape == (8, 64)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # labels are next-token shifted views of the same stream
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_loader_has_learnable_structure():
    ts = TokenStream(vocab_size=100, seq_len=128)
    b = ts.batch(step=0, batch_size=64)
    rep = (b["labels"][:, ::2] == b["tokens"][:, ::2]).mean()
    assert rep > 0.3  # the injected bigram structure


def test_prefetcher_orders_and_stops():
    ts = TokenStream(vocab_size=50, seq_len=16)
    pf = Prefetcher(lambda s: ts.batch(s, 8), start_step=0, depth=2)
    steps = [next(pf)[0] for _ in range(5)]
    assert steps == [0, 1, 2, 3, 4]
    pf.close()


def test_embedding_bridge():
    cfg = reduced(ARCHS["qwen3-14b"], n_layers=2)
    params = lm.init_params(jax.random.key(0), cfg)
    ts = TokenStream(vocab_size=cfg.vocab_size, seq_len=32)
    batches = [ts.batch(s, 8)["tokens"] for s in range(3)]
    vecs = embed_corpus(params, cfg, batches)
    assert vecs.shape == (24, cfg.d_model)
    assert np.isfinite(vecs).all()
    assert vecs.std() > 0

"""Data pipeline: loader determinism/shard-disjointness, prefetcher, and the
embedding bridge (zoo model → vectors → NOMAD-compatible)."""

import numpy as np
import pytest

import jax

from repro.configs import ARCHS, reduced
from repro.data.embeddings import embed_corpus
from repro.data.loader import Prefetcher, TokenStream
from repro.models import lm


def test_loader_determinism_and_shards():
    ts = TokenStream(vocab_size=1000, seq_len=64)
    b1 = ts.batch(step=5, batch_size=32)
    b2 = ts.batch(step=5, batch_size=32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ts.batch(step=6, batch_size=32)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # shards are deterministic and distinct
    s0 = ts.batch(step=5, batch_size=32, shard=0, n_shards=4)
    s1 = ts.batch(step=5, batch_size=32, shard=1, n_shards=4)
    assert s0["tokens"].shape == (8, 64)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # labels are next-token shifted views of the same stream
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_loader_has_learnable_structure():
    ts = TokenStream(vocab_size=100, seq_len=128)
    b = ts.batch(step=0, batch_size=64)
    rep = (b["labels"][:, ::2] == b["tokens"][:, ::2]).mean()
    assert rep > 0.3  # the injected bigram structure


def test_prefetcher_orders_and_stops():
    ts = TokenStream(vocab_size=50, seq_len=16)
    pf = Prefetcher(lambda s: ts.batch(s, 8), start_step=0, depth=2)
    steps = [next(pf)[0] for _ in range(5)]
    assert steps == [0, 1, 2, 3, 4]
    pf.close()


def test_prefetcher_builds_each_item_once_under_backpressure():
    """Back-pressure (full queue) must retry only the put — never rebuild
    the item: for the out-of-core store feed, a rebuild is a disk re-read."""
    import time

    calls = []
    pf = Prefetcher(lambda s: calls.append(s) or s * 10, depth=1)
    time.sleep(0.5)  # queue fills; worker now blocks on put, not on make
    got = [next(pf)[1] for _ in range(4)]
    pf.close()
    assert got == [0, 10, 20, 30]
    assert sorted(calls).count(0) == 1 and len(calls) == len(set(calls))


def test_prefetcher_max_steps_bounds_one_pass():
    calls = []
    pf = Prefetcher(lambda s: calls.append(s) or s, depth=2, max_steps=3)
    steps = [next(pf)[0] for _ in range(3)]
    pf._thread.join(timeout=2)  # worker exits on its own at max_steps
    pf.close()
    assert steps == [0, 1, 2] and calls == [0, 1, 2]


def test_prefetcher_surfaces_worker_exception():
    """A failed read must raise in the consumer, not hang it on a dead
    worker thread."""

    def make(step):
        if step == 2:
            raise ValueError("truncated shard")
        return step

    pf = Prefetcher(make, depth=2)
    assert next(pf)[1] == 0 and next(pf)[1] == 1
    with pytest.raises(ValueError, match="truncated shard"):
        next(pf)
    pf.close()


def test_stream_chunks_surfaces_read_error(tmp_path):
    """End-to-end: a shard that no longer matches meta.json fails the
    streamed pass with the store's error instead of deadlocking."""
    from repro.data.store import ShardedStore, stream_chunks, write_sharded

    x = np.arange(40, dtype=np.float32).reshape(10, 4)
    st = write_sharded(x, str(tmp_path / "s"), rows_per_shard=4)
    np.save(str(tmp_path / "s" / "shard-00001.npy"), np.zeros((2, 4), np.float32))
    fresh = ShardedStore(str(tmp_path / "s"))
    with pytest.raises(ValueError, match="does not match"):
        list(stream_chunks(fresh, 3))


def test_embedding_bridge():
    cfg = reduced(ARCHS["qwen3-14b"], n_layers=2)
    params = lm.init_params(jax.random.key(0), cfg)
    ts = TokenStream(vocab_size=cfg.vocab_size, seq_len=32)
    batches = [ts.batch(s, 8)["tokens"] for s in range(3)]
    vecs = embed_corpus(params, cfg, batches)
    assert vecs.shape == (24, cfg.d_model)
    assert np.isfinite(vecs).all()
    assert vecs.std() > 0

"""Differential tests for the fused ``nomad_step`` kernel.

Three layers of evidence that the fused custom VJP computes the same
mathematics as the legacy multi-pass path:

1. **AD parity** — jax.grad of the fused Pallas op vs jax.grad of the jnp
   oracle (ordinary AD through ``nomad_step_ref``), for every
   differentiable input (θ_i, θ_pos, θ_neg), and zero cotangents for the
   frozen ones (means / weights).
2. **Finite differences** — central-difference directional derivatives of
   the fused forward, independent of any AD path.
3. **Fit-level** — ``NomadProjection.fit`` with ``kernel_impl="pallas"``
   vs ``"jnp"`` for every strategy on a 1-device mesh. The two paths
   differ only in summation order (online K-tile accumulation vs one big
   sum), so embeddings track within a documented float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs.base import NomadConfig
from repro.core import losses
from repro.core.nomad import NomadProjection
from repro.data.synthetic import gaussian_mixture
from repro.kernels import registry
from repro.kernels.nomad_step.ref import nomad_step_ref


def _inputs(B=96, k=5, S=4, K=33, d=2, seed=0):
    spec = registry.get("nomad_step")
    sig = (
        ((B, d), "float32"),
        ((B, k, d), "float32"),
        ((B, k), "float32"),
        ((B, S, d), "float32"),
        ((B, S), "float32"),
        ((K, d), "float32"),
        ((K,), "float32"),
        ((B,), "int32"),
    )
    return spec.make_inputs(jax.random.key(seed), sig)


def _fused(*args):
    return jnp.mean(
        registry.dispatch("nomad_step", *args, impl="pallas", tiles={"bb": 512, "bk": 1024})
    )


def _oracle(*args):
    return jnp.mean(nomad_step_ref(*args))


# ---------------------------------------------------------------------------
# 1. custom VJP vs ordinary AD through the oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "shape", [(96, 5, 4, 33, 2), (512, 15, 16, 64, 2), (777, 15, 16, 130, 2)]
)
def test_fused_grads_match_oracle_ad(shape):
    B, k, S, K, d = shape
    args = _inputs(B, k, S, K, d, seed=B)
    got = jax.grad(_fused, argnums=(0, 1, 3))(*args)
    want = jax.grad(_oracle, argnums=(0, 1, 3))(*args)
    for g, w, name in zip(got, want, ("g_i", "g_pos", "g_neg")):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-6, err_msg=name
        )


def test_fused_value_matches_oracle():
    args = _inputs()
    np.testing.assert_allclose(
        float(_fused(*args)), float(_oracle(*args)), rtol=2e-6, atol=0
    )


def test_frozen_inputs_get_zero_cotangents():
    """means, weights and cell ids are non-differentiable by design: the
    custom VJP returns None for them, which AD must surface as zeros."""
    args = _inputs()
    g_pw, g_nw, g_mu, g_cw = jax.grad(_fused, argnums=(2, 4, 5, 6))(*args)
    for g in (g_pw, g_nw, g_mu, g_cw):
        np.testing.assert_array_equal(np.asarray(g), 0.0)


def test_nomad_loss_means_stopgrad_under_pallas():
    """Through the full nomad_loss seam the means stay stop-gradded."""
    B, k, S, K, d = 64, 5, 4, 16, 2
    keys = jax.random.split(jax.random.key(2), 5)
    theta = jax.random.normal(keys[0], (B, d))
    pos = jax.random.normal(keys[1], (B, k, d))
    pw = jax.random.uniform(keys[2], (B, k))
    neg = jax.random.normal(keys[3], (B, S, d))
    means = jax.random.normal(keys[4], (K, d))
    counts = jnp.full((K,), 10.0)
    own = jnp.zeros((B,), jnp.int32)

    def f(mu):
        return losses.nomad_loss(
            theta, pos, pw, mu, counts, own, neg, n_noise=8, n_total=160, impl="pallas"
        )

    np.testing.assert_array_equal(np.asarray(jax.grad(f)(means)), 0.0)


# ---------------------------------------------------------------------------
# 2. finite differences (AD-free check of the custom VJP)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("argnum,label", [(0, "theta_i"), (1, "theta_pos"), (3, "theta_neg")])
def test_fused_grad_matches_finite_differences(argnum, label):
    args = _inputs(B=16, k=3, S=2, K=9, d=2, seed=7)
    g = jax.grad(_fused, argnums=argnum)(*args)
    v = jax.random.normal(jax.random.key(99), args[argnum].shape)
    v = v / jnp.linalg.norm(v.reshape(-1))
    # the kernel computes in float32, so the difference quotient carries a
    # round-off floor of ~u·|f|/eps ≈ 1e-5 at eps=1e-2 — tolerance sits
    # above that floor, AD parity (tested above) covers the fine scale
    eps = 1e-2

    def at(t):
        shifted = list(args)
        shifted[argnum] = args[argnum] + t * v
        return float(_fused(*shifted))

    fd = (at(eps) - at(-eps)) / (2 * eps)
    analytic = float(jnp.vdot(g, v))
    np.testing.assert_allclose(fd, analytic, rtol=1e-2, atol=1e-4, err_msg=label)


# ---------------------------------------------------------------------------
# 3. fit-level: fused vs multipass per strategy (1-device mesh)
# ---------------------------------------------------------------------------

_N, _DIM = 1200, 8
_CFG = NomadConfig(
    n_points=_N,
    dim=_DIM,
    n_clusters=4,
    n_neighbors=10,
    n_noise=16,
    n_exact_negatives=4,
    batch_size=256,
    n_epochs=3,
)


@pytest.fixture(scope="module")
def fit_data():
    x, _ = gaussian_mixture(_N, _DIM, n_components=4, seed=0)
    return x


@pytest.fixture(scope="module")
def one_device_mesh():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))


@pytest.fixture(scope="module")
def one_device_pod_mesh():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("pod", "data"))


@pytest.mark.parametrize("strategy", ["local", "sharded", "hierarchical"])
def test_fit_fused_tracks_multipass_within_tolerance(
    fit_data, one_device_mesh, one_device_pod_mesh, strategy
):
    """Same RNG stream, same math, different summation order: the fused
    run must track the multipass run within float32 accumulation noise
    (documented tolerance: 1e-3 after 3 epochs of SGD amplification)."""
    mesh = {
        "local": None,
        "sharded": one_device_mesh,
        "hierarchical": one_device_pod_mesh,
    }[strategy]

    def run(impl):
        cfg = _CFG.replace(kernel_impl=impl)
        return NomadProjection(cfg, strategy=strategy, mesh=mesh).fit(fit_data)

    multipass = run("jnp")
    fused = run("pallas")
    np.testing.assert_allclose(fused.losses, multipass.losses, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        fused.embedding, multipass.embedding, rtol=1e-3, atol=1e-3
    )

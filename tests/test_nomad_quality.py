"""End-to-end NOMAD quality gates on synthetic data (single device, fast):
the map must beat chance by a wide margin, clusters must separate, the
InfoNC-t-SNE baseline must run, and the fit must be deterministic."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import NomadConfig
from repro.core.nomad import NomadProjection
from repro.data.synthetic import gaussian_mixture, hierarchical_mixture
from repro.metrics import neighborhood_preservation, random_triplet_accuracy
from repro.metrics.neighborhood import _topk_neighbors

CFG = NomadConfig(
    n_points=5000,
    dim=32,
    n_clusters=8,
    n_neighbors=15,
    n_noise=32,
    n_exact_negatives=8,
    batch_size=512,
    n_epochs=25,
)


@pytest.fixture(scope="module")
def fitted():
    x, labels = gaussian_mixture(5000, 32, n_components=8, seed=0)
    res = NomadProjection(CFG).fit(x)
    return x, labels, res


def test_quality_beats_chance(fitted):
    x, labels, res = fitted
    emb = res.embedding
    assert np.isfinite(emb).all()
    np10 = neighborhood_preservation(x, emb, k=10, n_queries=500)
    assert np10 > 10 * (10 / 5000), np10  # ≥10× chance
    rta = random_triplet_accuracy(x, emb, 10_000)
    assert rta > 0.6, rta


def test_cluster_separation(fitted):
    x, labels, res = fitted
    emb = res.embedding
    nb = np.asarray(_topk_neighbors(jnp.asarray(emb[:500]), jnp.asarray(emb), 10))
    purity = np.mean(labels[nb] == labels[:500, None])
    assert purity > 0.9, purity


def test_fit_deterministic():
    x, _ = gaussian_mixture(2000, 16, n_components=4, seed=1)
    cfg = CFG.replace(n_points=2000, dim=16, n_clusters=4, n_epochs=5)
    r1 = NomadProjection(cfg).fit(x)
    r2 = NomadProjection(cfg).fit(x, index=r1.index)
    np.testing.assert_array_equal(r1.embedding, r2.embedding)


def test_infonc_baseline_runs_and_optimizes():
    x, _ = gaussian_mixture(2000, 16, n_components=4, seed=2)
    cfg = CFG.replace(n_points=2000, dim=16, n_clusters=4, n_epochs=10)
    res = NomadProjection(cfg, method="infonc").fit(x)
    assert np.isfinite(res.embedding).all()
    rta = random_triplet_accuracy(x, res.embedding, 8000)
    assert rta > 0.55, rta


def test_multiscale_structure():
    """Fig. 4 analogue: super-cluster structure must survive in 2-D."""
    x, sup, sub = hierarchical_mixture(4000, 24, n_super=4, n_sub=3, seed=3)
    cfg = CFG.replace(n_points=4000, dim=24, n_clusters=8, n_epochs=25)
    res = NomadProjection(cfg).fit(x)
    emb = res.embedding
    nb = np.asarray(_topk_neighbors(jnp.asarray(emb[:400]), jnp.asarray(emb), 10))
    sup_purity = np.mean(sup[nb] == sup[:400, None])
    assert sup_purity > 0.8, sup_purity
